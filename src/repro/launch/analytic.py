"""Exact analytic op-level cost model per (arch x shape): FLOPs and
first-order HBM bytes.

Why this exists (EXPERIMENTS.md §Roofline, methodology): XLA cost analysis on
the CPU backend counts while-loop bodies ONCE.  The layer scan is corrected by
block-scaling, but scans *inside* a layer (attention kv-block scan, mamba
chunk scan, sLSTM time scan) are still undercounted — measured 2.69e15 vs
4.85e15 true FLOPs for llama3.2-1b prefill_32k — and "bytes accessed" is a
pre-fusion overestimate.  This module enumerates every matmul in the model
(the same einsums the code executes) so the compute term is exact; bytes use
the standard one-pass GEMM model (read operands + write result, x4 for
training fwd+bwd+remat, + parameter/optimizer/KV-cache traffic).  HLO-derived
numbers are reported alongside for validation on cells where scans are flat.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)

    __rmul__ = __mul__


def _gemm(m: float, k: float, n: float, dt: int = 2) -> Cost:
    """C[m,n] = A[m,k] @ B[k,n]: 2mkn flops; read A,B write C."""
    return Cost(2.0 * m * k * n, dt * (m * k + k * n + m * n))


def _ew(elems: float, flops_per: float = 1.0, dt: int = 2) -> Cost:
    return Cost(flops_per * elems, 2 * dt * elems)


def _attention(cfg: ModelConfig, tokens: float, s_kv_eff: float,
               cross_kv_tokens: float = 0.0) -> Cost:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    c = _gemm(tokens, d, qd)                      # q proj
    kv_tokens = cross_kv_tokens or tokens
    c += _gemm(kv_tokens, d, kvd) * 2             # k, v proj
    c += _gemm(tokens, qd, d)                     # out proj
    # scores + pv: per token 2*s_kv*H*hd each
    c += Cost(4.0 * tokens * s_kv_eff * qd,
              2 * 2 * tokens * s_kv_eff * cfg.n_heads)  # score tensor rw (bf16-ish)
    if cfg.qk_norm:
        c += _ew(tokens * qd, 6) + _ew(kv_tokens * kvd, 6)
    return c


def _dense_mlp(cfg: ModelConfig, tokens: float) -> Cost:
    d, f = cfg.d_model, cfg.d_ff
    return _gemm(tokens, d, f) * 2 + _gemm(tokens, f, d) + _ew(tokens * f, 4)


def _moe(cfg: ModelConfig, tokens: float) -> Cost:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e, k = cfg.n_experts, cfg.top_k
    gt = cfg.moe_group_size
    cap_per_tok = (gt if gt <= 64 else gt * k * cfg.capacity_factor / e) * e / gt
    c = _gemm(tokens, d, e)                                    # router
    c += Cost(4.0 * tokens * cap_per_tok * d, 0)               # dispatch+combine
    c += (_gemm(tokens * k, d, f) * 2 + _gemm(tokens * k, f, d))  # experts
    # expert weights traffic: each expert's weights stream once per group set
    c += Cost(0, 3 * e * d * f * 2)
    c += _ew(tokens * k * f, 4)
    return c


def _mamba(cfg: ModelConfig, tokens: float) -> Cost:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank
    w = cfg.ssm_conv_width
    c = _gemm(tokens, d, 2 * di)                 # in_proj
    c += _ew(tokens * di, 2 * w)                 # causal conv
    c += _gemm(tokens, di, r + 2 * n)            # x_proj
    c += _gemm(tokens, r, di)                    # dt_proj
    levels = max(1, math.ceil(math.log2(max(cfg.ssm_chunk, 2))))
    c += Cost(3.0 * tokens * di * n * levels,
              4 * 4 * tokens * di * n)           # assoc scan (f32 state)
    c += Cost(2.0 * tokens * di * n, 4 * tokens * di * n)  # y = C.h
    c += _ew(tokens * di, 6)                     # D skip + gate
    c += _gemm(tokens, di, d)                    # out_proj
    return c


def _mlstm(cfg: ModelConfig, tokens: float) -> Cost:
    d, di = cfg.d_model, cfg.mlstm_inner
    h = cfg.n_heads
    hd = di // h
    tc = min(cfg.ssm_chunk, 128)
    c = _gemm(tokens, d, 2 * di)
    c += _gemm(tokens, di, di) * 3               # q,k,v
    c += _gemm(tokens, di, 2 * h)                # gates
    # intra-chunk quadratic: scores, h_intra, n_intra ~ 6*Tc*di per token
    c += Cost(6.0 * tokens * tc * di, 4 * tokens * tc * h)
    # inter-chunk: q@C and state update ~ 4*di*hd per token
    c += Cost(4.0 * tokens * di * hd, 4 * tokens * di / tc * hd * 2)
    c += _gemm(tokens, di, d)
    return c


def _slstm(cfg: ModelConfig, tokens: float) -> Cost:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    p = int(d * cfg.xlstm_slstm_proj)
    c = _gemm(tokens, d, 4 * d)                  # input proj
    c += Cost(2.0 * tokens * 4 * d * hd, 4 * 4 * tokens * d)  # recurrent (seq)
    c += _ew(tokens * 4 * d, 8, dt=4)
    c += _gemm(tokens, d, 2 * p) + _gemm(tokens, p, d)
    return c


def _layer(cfg: ModelConfig, spec: LayerSpec, tokens: float, s_kv: float,
           cross_kv: float = 0.0) -> Cost:
    c = _ew(tokens * cfg.d_model, 6, dt=2)  # norms + residuals
    if spec.mixer == "attn":
        c += _attention(cfg, tokens, s_kv)
    elif spec.mixer == "mamba":
        c += _mamba(cfg, tokens)
    elif spec.mixer == "mlstm":
        c += _mlstm(cfg, tokens)
    elif spec.mixer == "slstm":
        c += _slstm(cfg, tokens)
    if cross_kv:
        c += _attention(cfg, tokens, cross_kv, cross_kv_tokens=cross_kv)
    if spec.mlp == "dense":
        c += _dense_mlp(cfg, tokens)
    elif spec.mlp == "moe":
        c += _moe(cfg, tokens)
    return c


def _s_kv_eff(cfg: ModelConfig, s: float, causal: bool = True) -> float:
    eff = (s + 1) / 2 if causal else s
    if cfg.sliding_window:
        eff = min(eff, cfg.sliding_window)
    return eff


def forward_cost(cfg: ModelConfig, batch: int, seq: int) -> Cost:
    tokens = float(batch * seq)
    if cfg.encoder_decoder:
        enc_tok = dec_tok = tokens / 2  # 50/50 split (DESIGN.md §6)
        enc_seq = dec_seq = seq / 2
        c = Cost()
        enc_spec = LayerSpec("attn", "dense")
        c += cfg.n_encoder_layers * _layer(
            cfg, enc_spec, enc_tok, _s_kv_eff(cfg, enc_seq, causal=False))
        for spec in cfg.pattern:
            c += cfg.n_repeats * _layer(cfg, spec, dec_tok,
                                        _s_kv_eff(cfg, dec_seq),
                                        cross_kv=enc_seq)
        c += _gemm(dec_tok, cfg.d_model, cfg.padded_vocab)  # unembed
        return c
    c = Cost(0, tokens * cfg.d_model * 2)  # embedding gather traffic
    s_kv = _s_kv_eff(cfg, seq)
    for spec in cfg.pattern:
        c += cfg.n_repeats * _layer(cfg, spec, tokens, s_kv)
    c += _gemm(tokens, cfg.d_model, cfg.padded_vocab)
    return c


def _param_bytes(cfg: ModelConfig) -> float:
    import numpy as np
    return cfg.param_count() * 2.0  # bf16


def train_cost(cfg: ModelConfig, shape: ShapeConfig) -> Cost:
    fwd = forward_cost(cfg, shape.global_batch, shape.seq_len)
    mult = 3.0 + (1.0 if cfg.remat == "full" else 0.0)  # fwd+bwd(2x)+remat
    c = Cost(fwd.flops * mult, fwd.bytes * mult)
    p = _param_bytes(cfg)
    opt_b = 2.0 if cfg.opt_state_dtype == "bfloat16" else 4.0
    # grads write+read, two moments read+write, params read(+w in fwd counted)
    c += Cost(2.0 * cfg.param_count(), p * 2 + 2 * p / 2 * opt_b * 2 + p)
    return c


def prefill_cost(cfg: ModelConfig, shape: ShapeConfig) -> Cost:
    return forward_cost(cfg, shape.global_batch, shape.seq_len)


def decode_cost(cfg: ModelConfig, shape: ShapeConfig) -> Cost:
    b = float(shape.global_batch)
    s = float(shape.seq_len)
    hd = cfg.resolved_head_dim
    c = Cost()
    if cfg.encoder_decoder:
        s = s / 2  # self cache + cross cache, each seq/2
    for spec in cfg.pattern:
        tokens = b  # one token per sequence
        cc = _ew(tokens * cfg.d_model, 6)
        if spec.mixer == "attn":
            cc += _attention(cfg, tokens, 1.0)  # projections (s_kv 1: proj only)
            s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
            cache_tensor = b * s * cfg.n_kv_heads * hd * 2  # bytes, one of k/v
            # K and V each read once for scores / pv
            cc += Cost(4.0 * tokens * s_eff * cfg.n_heads * hd,
                       2.0 * cache_tensor)
            if cfg.decode_ring:
                # two-tier: per-step writes touch only the ring (§Perf decode)
                ring_tensor = b * cfg.decode_ring * cfg.n_kv_heads * hd * 2
                cc += Cost(0, 2.0 * 2.0 * ring_tensor)
            else:
                # masked ring-buffer update rewrites both cache tensors
                cc += Cost(0, 2.0 * 2.0 * cache_tensor)
        elif spec.mixer == "mamba":
            cc += _mamba(cfg, tokens)
            cc += Cost(0, b * cfg.d_inner * cfg.ssm_state_dim * 4 * 2)
        elif spec.mixer == "mlstm":
            cc += _mlstm(cfg, tokens)
            h = cfg.n_heads
            hdm = cfg.mlstm_inner // h
            cc += Cost(0, b * h * hdm * hdm * 4 * 2)
        elif spec.mixer == "slstm":
            cc += _slstm(cfg, tokens)
        if cfg.encoder_decoder:
            cc += _attention(cfg, tokens, s, cross_kv_tokens=0.0001)
            cc += Cost(0, 2.0 * b * s * cfg.n_kv_heads * hd * 2 * 2)
        if spec.mlp == "dense":
            cc += _dense_mlp(cfg, tokens)
        elif spec.mlp == "moe":
            cc += _moe(cfg, tokens)
        c += cfg.n_repeats * cc
    c += _gemm(b, cfg.d_model, cfg.padded_vocab)
    # every (active-ish) weight is read once per step regardless of batch
    c += Cost(0, _param_bytes(cfg))
    return c


def cell_cost(cfg: ModelConfig, shape: ShapeConfig) -> Cost:
    if shape.kind == "train":
        return train_cost(cfg, shape)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape)
    return decode_cost(cfg, shape)
