"""Declarative query CLI: run JSON ``QuerySpec`` s against a TASTI index.

Specs are the engine's JSON form — one query each.  By default the whole
list executes as one :class:`~repro.core.session.QuerySession`: specs over
the same score are planned jointly (propagation once per mode, shared
stratified sample for aggregations), their first samples are prefetched
through the oracle broker in combined microbatches, and the output reports
per-spec *and* session-level label accounting.  ``--isolated`` falls back to
executing specs one-by-one (shared label cache only); with ``--crack``,
every fresh annotation is folded back into the index either way:

    PYTHONPATH=src python -m repro.launch.query \\
        --workload night-street --n-frames 3000 --quick \\
        --spec '{"kind": "aggregation", "score": "score_count", "err": 0.05}' \\
        --spec '{"kind": "limit", "score": "score_rare", "k_results": 5}' \\
        --session-budget 2000 --oracle-batch 64 --crack

Point ``--index`` at a saved index (see ``repro.launch.build_index``) to skip
construction; otherwise a TASTI index is built in-process first.
"""
from __future__ import annotations

import argparse
import json

from repro.core.codec import result_row as _result_row
from repro.core.engine import QueryEngine, QuerySpec
from repro.core.index import TastiIndex
from repro.core.pipeline import build_tasti, cli_tasti_config
from repro.core.queries.registry import registered_kinds
from repro.core.schema import WORKLOAD_NAMES, make_workload
from repro.core.session import QuerySession


def _load_specs(args) -> list:
    raw = []
    if args.specs_file:
        with open(args.specs_file) as f:
            body = json.load(f)
        if not isinstance(body, list):
            raise SystemExit(f"--specs-file must hold a JSON list of specs, "
                             f"got {type(body).__name__}")
        raw.extend(body)
    for s in args.spec or []:
        raw.append(json.loads(s))
    if not raw:
        raise SystemExit("no queries: pass --spec JSON (repeatable) and/or "
                         "--specs-file; known kinds: "
                         f"{registered_kinds()}")
    return [QuerySpec.from_dict(d) for d in raw]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="execute declarative QuerySpecs against a TASTI index")
    ap.add_argument("--workload", default="night-street",
                    choices=list(WORKLOAD_NAMES))
    ap.add_argument("--n-frames", type=int, default=8000,
                    help="records in the (synthetic) workload")
    ap.add_argument("--index", default=None,
                    help="path stem of a saved index to load; omit to build")
    ap.add_argument("--variant", default="T", choices=["T", "PT"])
    ap.add_argument("--n-train", type=int, default=400)
    ap.add_argument("--n-reps", type=int, default=800)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--triplet-steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true",
                    help="tiny build budgets (smoke tests / CI)")
    ap.add_argument("--crack", action="store_true",
                    help="fold every query's fresh annotations back into the "
                         "index (cracking feedback loop, paper §3.3)")
    ap.add_argument("--isolated", action="store_true",
                    help="execute specs one-by-one instead of as a jointly-"
                         "planned session (shared label cache only)")
    ap.add_argument("--session-budget", type=int, default=None,
                    help="combined worst-case oracle budget for the session "
                         "(allocated across specs at plan time)")
    ap.add_argument("--oracle-batch", type=int, default=64,
                    help="max ids per target_dnn_batch microbatch issued by "
                         "the oracle broker")
    ap.add_argument("--oracle-replicas", type=int, default=1,
                    help="target-DNN replica workers behind the broker's "
                         "microbatcher; results are identical at any count, "
                         "flushes overlap across replicas")
    ap.add_argument("--oracle-backend", default="thread",
                    choices=["thread", "process"],
                    help="replica worker kind: threads (GIL-releasing "
                         "targets) or forked worker processes (compute-"
                         "bound oracles; see docs/runbook.md)")
    ap.add_argument("--save-index", default=None,
                    help="path stem to persist the (possibly cracked) index")
    ap.add_argument("--spec", action="append",
                    help="QuerySpec as JSON (repeatable, run in order)")
    ap.add_argument("--specs-file", default=None,
                    help="file holding a JSON list of QuerySpecs")
    args = ap.parse_args(argv)
    if args.isolated and args.session_budget is not None:
        ap.error("--session-budget needs session planning; drop --isolated")

    specs = _load_specs(args)
    wl = make_workload(args.workload, n_records=args.n_frames)

    if args.index:
        index = TastiIndex.load(args.index)
        if index.n_records != len(wl.features):
            raise SystemExit(
                f"index covers {index.n_records} records but workload "
                f"{wl.name} has {len(wl.features)}; pass matching --n-frames")
    else:
        cfg = cli_tasti_config(args.quick, n_train=args.n_train,
                               n_reps=args.n_reps, k=args.k,
                               triplet_steps=args.triplet_steps)
        index = build_tasti(wl, cfg, variant=args.variant).index

    engine = QueryEngine(index, wl, crack=args.crack,
                         max_oracle_batch=args.oracle_batch,
                         oracle_replicas=args.oracle_replicas,
                         oracle_backend=args.oracle_backend)
    session_stats = None
    rows = []
    if args.isolated:
        for spec in specs:
            rows.append(_result_row(engine.execute(spec)))
    else:
        out = QuerySession(engine, specs,
                           budget=args.session_budget).execute()
        rows = [_result_row(r) for r in out.results]
        session_stats = {**out.stats, "trace": out.plan.trace}

    if args.save_index:
        index.save(args.save_index)

    print(json.dumps({
        "workload": wl.name,
        "records": index.n_records,
        "reps": index.n_reps,
        "index_version": index.version,
        "engine": engine.stats,
        "broker": engine.broker.stats,
        "session": session_stats,
        "results": rows,
    }, indent=2))


if __name__ == "__main__":
    main()
