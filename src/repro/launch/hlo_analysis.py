"""Post-SPMD HLO analysis: per-device collective wire bytes, scaled through
while-loop bodies (scan trip counts parsed from loop conditions).

``cost_analysis()`` does not report collective traffic, and counts while
bodies once; this module parses ``compiled.as_text()`` instead:

* every ``all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute`` op contributes *wire bytes per device* using ring
  formulas over its replica-group size g:
    - all-reduce:      2 (g-1)/g * result_bytes
    - all-gather:        (g-1)/g * result_bytes
    - reduce-scatter:    (g-1)/g * operand_bytes (= result*g)
    - all-to-all:        (g-1)/g * result_bytes
    - collective-permute:            result_bytes
* computations reachable through ``while`` bodies are multiplied by the trip
  count extracted from the loop condition's comparison constant.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)")
_COND_OF_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups, group_size]
        return int(m.group(2))
    return default


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if op == "all-reduce":
        return 2.0 * frac * result_bytes
    if op == "all-gather":
        return frac * result_bytes
    if op == "reduce-scatter":
        return frac * result_bytes * g
    if op == "all-to-all":
        return frac * result_bytes
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """Split HLO text into {computation_name: [lines]}."""
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    depth = 0
    for line in hlo.splitlines():
        if current is None:
            m = _COMP_START_RE.match(line)
            if m and "{" in line:
                current = m.group(1)
                comps[current] = []
                depth = line.count("{") - line.count("}")
                if depth <= 0:
                    current = None
        else:
            depth += line.count("{") - line.count("}")
            comps[current].append(line)
            if depth <= 0:
                current = None
    return comps


def analyze_collectives(hlo: str, default_group: int) -> Dict[str, object]:
    """Returns {'wire_bytes_per_device', 'op_counts', 'by_op_bytes', 'loops'}."""
    comps = parse_computations(hlo)

    # trip counts: while ops referencing condition + body computations
    trip_of_body: Dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _COND_OF_WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                consts = [int(c) for c in _CONST_RE.findall(
                    "\n".join(comps.get(cond, [])))]
                trip_of_body[body] = max(consts) if consts else 1

    # collectives + nested while refs per computation
    local_bytes: Dict[str, float] = defaultdict(float)
    local_counts: Dict[str, Dict[str, int]] = defaultdict(lambda: defaultdict(int))
    children: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            mc = _COLL_RE.search(line)
            if mc:
                btys = _type_bytes(mc.group(1))
                g = _group_size(line, default_group)
                op = mc.group(2)
                local_bytes[name] += _wire_bytes(op, btys, g)
                local_counts[name][op] += 1
            mw = _WHILE_RE.search(line)
            if mw:
                body = mw.group(1)
                children[name].append((body, trip_of_body.get(body, 1)))

    memo: Dict[str, float] = {}
    count_memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, seen=()) -> float:
        if name in memo:
            return memo[name]
        if name in seen:
            return 0.0
        t = local_bytes.get(name, 0.0)
        for body, trips in children.get(name, ()):
            t += trips * total(body, seen + (name,))
        memo[name] = t
        return t

    def total_counts(name: str, seen=()) -> Dict[str, float]:
        if name in count_memo:
            return count_memo[name]
        if name in seen:
            return {}
        out: Dict[str, float] = defaultdict(float)
        for op, c in local_counts.get(name, {}).items():
            out[op] += c
        for body, trips in children.get(name, ()):
            for op, c in total_counts(body, seen + (name,)).items():
                out[op] += trips * c
        count_memo[name] = dict(out)
        return count_memo[name]

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: sum every computation once (upper-ish bound)
        wire = sum(local_bytes.values())
        counts = defaultdict(float)
        for c in local_counts.values():
            for op, n in c.items():
                counts[op] += n
        loops = {}
    else:
        wire = total(entry)
        counts = total_counts(entry)
        loops = {b: t for b, t in trip_of_body.items()}
    return {
        "wire_bytes_per_device": float(wire),
        "op_counts": {k: float(v) for k, v in counts.items()},
        "loops": loops,
    }
