"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod 16x16 mesh:

    compute    = HLO_FLOPs  / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes  / (chips * 819e9   B/s HBM)
    collective = wire_bytes / (chips * 50e9    B/s per ICI link)

XLA cost analysis reports per-device numbers and counts scan bodies once, so
totals use the scan-body scaling validated in EXPERIMENTS.md §Roofline:

    per_device_total = full_graph + (n_repeats - 1) * block_graph

(the ``__block`` JSONs are the standalone layer-block lowerings with identical
shardings).  Collective wire bytes already include while-body trip scaling
from the HLO parser, so they come straight from the full graph.

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N_active*B (decode); the
ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.

    PYTHONPATH=src python -m repro.launch.roofline [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import List, Optional

from repro.configs import (ASSIGNED_ARCHS, SHAPE_BY_NAME, SHAPES,
                           cell_is_runnable, get_config)
from repro.launch import analytic
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _load(tag: str) -> Optional[dict]:
    p = DRYRUN_DIR / f"{tag}.json"
    if not p.exists():
        return None
    d = json.loads(p.read_text())
    return d if d.get("status") == "ok" else None


def model_flops(arch: str, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); enc-dec tokens split 50/50 so the
    effective token count is halved (each token crosses ~half the stack)."""
    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if cfg.encoder_decoder:
        tokens /= 2
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def analyze_cell(arch: str, shape, mesh: str = "single",
                 variant: str = "") -> Optional[dict]:
    suffix = f"__{variant}" if variant else ""
    full = _load(f"{arch}__{shape.name}__{mesh}{suffix}")
    if full is None:
        return None
    block = _load(f"{arch}__{shape.name}__{mesh}__block{suffix}")
    r = full["n_repeats"]
    chips = full["n_devices"]

    def scaled(key: str) -> float:
        v = full.get(key) or 0.0
        if block and block.get(key):
            v += (r - 1) * block[key]
        return v

    hlo_flops_dev = scaled("flops_per_device")
    hlo_bytes_dev = scaled("bytes_accessed_per_device")
    # collectives: block-scaled like flops (HLO trip parsing is unreliable
    # for jax's "wide" scan lowering); the full graph already holds one body.
    # Train blocks differentiate wrt activations only (specs.py), so the
    # stacked param-grad all-reduce is counted exactly once, in the full
    # graph.
    wire_dev = full.get("wire_bytes_per_device") or 0.0
    if block and block.get("wire_bytes_per_device"):
        wire_dev += (r - 1) * block["wire_bytes_per_device"]

    # primary terms: exact analytic op model (see launch/analytic.py — HLO
    # undercounts intra-layer scans and overcounts pre-fusion bytes)
    import dataclasses
    cfg = get_config(arch)
    overrides = full.get("overrides") or {}
    if overrides:
        typed = {k: type(getattr(cfg, k))(v) for k, v in overrides.items()}
        cfg = dataclasses.replace(cfg, **typed)
    cost = analytic.cell_cost(cfg, shape)
    flops_dev = cost.flops / chips
    bytes_dev = cost.bytes / chips

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape)
    bound_s = max(compute_s, memory_s, collective_s)
    ideal_s = mf / (chips * PEAK_FLOPS_BF16)
    if shape.kind == "decode":
        # decode is irreducibly memory-bound: the ideal step time is the
        # minimal traffic (params + one cache read; ring-buffered writes)
        min_cfg = dataclasses.replace(cfg, decode_ring=cfg.decode_ring or 256)
        min_bytes = analytic.cell_cost(min_cfg, shape).bytes
        ideal_s = max(ideal_s, min_bytes / (chips * HBM_BW))
    return {
        "arch": arch,
        "shape": shape.name,
        "mesh": mesh,
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "analytic_flops_global": cost.flops,
        "analytic_bytes_global": cost.bytes,
        "hlo_flops_global": hlo_flops_dev * chips,
        "hlo_bytes_global": hlo_bytes_dev * chips,
        "hlo_vs_analytic_flops": (hlo_flops_dev * chips) / cost.flops
        if cost.flops else 0.0,
        "useful_ratio": mf / cost.flops if cost.flops else 0.0,
        # fraction of roofline: ideal (model-FLOPs-limited) time over the
        # dominant-term time — the score we hillclimb
        "roofline_fraction": ideal_s / bound_s if bound_s else 0.0,
        "peak_memory_gib": (full.get("peak_memory_bytes") or 0) / 2 ** 30,
        "block_scaled": block is not None,
        "variant": variant,
    }


def full_table(mesh: str = "single") -> List[dict]:
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            if not cell_is_runnable(arch, shape):
                rows.append({"arch": arch, "shape": shape.name, "mesh": mesh,
                             "skipped": True})
                continue
            cell = analyze_cell(arch, shape, mesh)
            if cell:
                rows.append(cell)
    return rows


def format_table(rows: List[dict]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'peakGiB':>8s} {'hlo/ana':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"{r['arch']:26s} {r['shape']:12s} "
                         f"{'— skipped (full attention @500k)':>40s}")
            continue
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:7.2f} {r['peak_memory_gib']:8.2f} "
            f"{r['hlo_vs_analytic_flops']:8.3f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json", default="")
    ap.add_argument("--compare", nargs=3, metavar=("ARCH", "SHAPE", "VARIANT"),
                    action="append", default=[],
                    help="print baseline vs variant for one cell")
    args = ap.parse_args()
    if args.compare:
        for arch, shape_name, variant in args.compare:
            shape = SHAPE_BY_NAME[shape_name]
            base = analyze_cell(arch, shape, args.mesh)
            var = analyze_cell(arch, shape, args.mesh, variant=variant)
            print(format_table([r for r in (base, var) if r]))
            if base and var:
                for term in ("compute_s", "memory_s", "collective_s"):
                    b, v = base[term], var[term]
                    print(f"  {term}: {b:.4f} -> {v:.4f} "
                          f"({b/max(v,1e-12):.2f}x)")
                print(f"  roofline: {100*base['roofline_fraction']:.2f}% -> "
                      f"{100*var['roofline_fraction']:.2f}%")
        return
    rows = full_table(args.mesh)
    print(format_table(rows))
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
