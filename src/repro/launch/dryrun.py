import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count on first init, and the production meshes need 512 host devices.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
    python -m repro.launch.dryrun --arch ... --shape ... --mesh multi --block

Writes one JSON per cell to experiments/dryrun/.  ``--block`` additionally
lowers the standalone layer-block for the roofline's scan-body scaling
(DESIGN.md §5).  Run cells in separate processes (see run_all_dryruns.py) to
bound compiler memory.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import SHAPE_BY_NAME, cell_is_runnable, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, block: bool = False,
             attn_impl: str = "xla", overrides: dict = None) -> dict:
    import dataclasses

    from repro.launch import specs as specs_lib

    cfg = get_config(arch)
    if overrides:
        typed = {}
        for k, v in overrides.items():
            cur = getattr(cfg, k)
            typed[k] = type(cur)(v) if cur is not None else v
        cfg = dataclasses.replace(cfg, **typed)
    shape = SHAPE_BY_NAME[shape_name]
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "block": block, "status": "skipped"}
    if not cell_is_runnable(arch, shape):
        result["reason"] = ("long_500k requires sub-quadratic attention; "
                            f"{arch} is pure full-attention (DESIGN.md §6)")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:  # jax < 0.5: ambient mesh via the context-manager protocol
        mesh.__enter__()
    if block:
        cell = specs_lib.build_block_cell(cfg, shape, mesh, attn_impl=attn_impl)
    else:
        cell = specs_lib.build_cell(cfg, shape, mesh, attn_impl=attn_impl)

    t0 = time.time()
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
    lowered = jitted.lower(*cell.args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    n_devices = len(mesh.devices.flatten())
    coll = hlo_analysis.analyze_collectives(hlo, default_group=n_devices)

    result.update({
        "status": "ok",
        "overrides": overrides or {},
        "kind": cell.static["kind"],
        "n_devices": n_devices,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": float(ca.get("flops", -1.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", -1.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "peak_memory_bytes": getattr(ma, "peak_memory_in_bytes", None),
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "wire_bytes_per_device": coll["wire_bytes_per_device"],
        "collective_op_counts": coll["op_counts"],
        "loop_trip_counts": coll["loops"],
        "hlo_size": len(hlo),
        "n_repeats": cfg.n_repeats,
    })
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--block", action="store_true",
                    help="lower one layer-block (roofline scan-body scaling)")
    ap.add_argument("--attn-impl", default="xla")
    ap.add_argument("--out-dir", default=str(OUT_DIR))
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. shard_strategy=pure_dp)")
    ap.add_argument("--tag", default="", help="variant suffix for the output file")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.mesh}" + ("__block" if args.block else "")
    if args.tag:
        tag += f"__{args.tag}"
    out_path = out_dir / f"{tag}.json"
    overrides = dict(kv.split("=", 1) for kv in args.set)

    try:
        result = run_cell(args.arch, args.shape, args.mesh, block=args.block,
                          attn_impl=args.attn_impl, overrides=overrides)
    except Exception as e:  # record failures as data, not crashes
        result = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "block": args.block, "status": "error",
                  "overrides": overrides,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    out_path.write_text(json.dumps(result, indent=2))
    status = result["status"]
    extra = ""
    if status == "ok":
        extra = (f" compile={result['compile_s']}s"
                 f" flops/dev={result['flops_per_device']:.3e}"
                 f" peak={result['peak_memory_bytes']}")
    elif status == "error":
        extra = " " + result["error"][:200]
    print(f"[dryrun] {tag}: {status}{extra}")


if __name__ == "__main__":
    main()
