"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:  # older jax: every mesh axis is Auto already
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (same axis names as single-pod)."""
    return _make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
CHIPS_PER_POD = 256
