"""Abstract input specs per (arch x shape x mesh) cell.

Everything is ``jax.ShapeDtypeStruct`` with a ``NamedSharding`` attached — the
same pattern shannon/kernels uses: weak-type-correct, shardable, and zero
device allocation, so a 398B-parameter training step lowers on a laptop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.common import PyTree, abstract_params
from repro.optim.adamw import OptimizerConfig, opt_state_specs
from repro.parallel import sharding as shd
from repro.train import steps as steps_lib


def _with_sharding(specs: PyTree, shardings: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs, shardings)


def _seq_split(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int]:
    """(enc_len, dec_len): enc-dec archs split context 50/50 (DESIGN.md §6)."""
    if cfg.encoder_decoder:
        return shape.seq_len // 2, shape.seq_len // 2
    return 0, shape.seq_len


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Tuple[PyTree, PyTree]:
    """(abstract batch, shardings) for a train/prefill cell."""
    b = shape.global_batch
    enc_len, s = _seq_split(cfg, shape)
    bspec = shd.batch_pspec(mesh, b, extra_dims=1,
                            strategy=cfg.shard_strategy)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch: Dict[str, Any] = {"tokens": tok}
    shardings: Dict[str, Any] = {"tokens": NamedSharding(mesh, bspec)}
    if shape.kind == "train":
        batch["targets"] = tok
        shardings["targets"] = NamedSharding(mesh, bspec)
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        shardings["vision_embeds"] = NamedSharding(
            mesh, shd.batch_pspec(mesh, b, extra_dims=2))
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, enc_len, cfg.d_model), jnp.dtype(cfg.dtype))
        shardings["enc_embeds"] = NamedSharding(
            mesh, shd.batch_pspec(mesh, b, extra_dims=2))
    return _with_sharding(batch, shardings), shardings


@dataclasses.dataclass
class Cell:
    """Everything needed to AOT-lower one (arch x shape x mesh) cell."""
    fn: Callable
    args: Tuple[Any, ...]          # abstract ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    static: Dict[str, Any]


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opt: Optional[OptimizerConfig] = None,
               attn_impl: str = "xla") -> Cell:
    pspecs = lm.model_specs(cfg)
    if shape.kind == "train":
        opt = opt or OptimizerConfig(state_dtype=cfg.opt_state_dtype)
        psh = shd.param_shardings(pspecs, cfg, mesh)
        params = _with_sharding(abstract_params(pspecs), psh)
        ospecs = opt_state_specs(pspecs, opt)
        mu_ps = shd.opt_pspecs(ospecs["mu"], cfg, mesh)
        nu_ps = shd.opt_pspecs(ospecs["nu"], cfg, mesh)
        osh = {"mu": jax.tree.map(lambda p: NamedSharding(mesh, p), mu_ps),
               "nu": jax.tree.map(lambda p: NamedSharding(mesh, p), nu_ps),
               "step": NamedSharding(mesh, P())}
        ostate = {"mu": _with_sharding(abstract_params(ospecs["mu"]), osh["mu"]),
                  "nu": _with_sharding(abstract_params(ospecs["nu"]), osh["nu"]),
                  "step": jax.ShapeDtypeStruct((), jnp.int32,
                                               sharding=osh["step"])}
        batch, bsh = batch_specs(cfg, shape, mesh)
        fn = steps_lib.make_train_step(cfg, opt, attn_impl=attn_impl)
        return Cell(fn, (params, ostate, batch), (psh, osh, bsh),
                    (psh, osh, None), {"kind": "train"})

    serve_fsdp = cfg.fsdp or shd.serve_needs_fsdp(cfg, mesh)
    psh = shd.param_shardings(pspecs, cfg, mesh, fsdp=serve_fsdp)
    params = _with_sharding(abstract_params(pspecs), psh)

    if shape.kind == "prefill":
        batch, bsh = batch_specs(cfg, shape, mesh)
        fn = steps_lib.make_prefill_step(cfg, attn_impl=attn_impl)
        return Cell(fn, (params, batch), (psh, bsh), None,
                    {"kind": "prefill"})

    # decode: one new token over a seq_len cache
    b = shape.global_batch
    enc_len, s = _seq_split(cfg, shape)
    cspecs = lm.cache_specs(cfg, b, s, cross_len=enc_len)
    csh = shd.cache_shardings(cspecs, cfg, mesh, b)
    caches = _with_sharding(cspecs, csh)
    tok_sh = NamedSharding(mesh, shd.batch_pspec(mesh, b, extra_dims=1))
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=tok_sh)
    pos_sh = NamedSharding(mesh, P())
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=pos_sh)
    fn = steps_lib.make_serve_step(cfg)
    return Cell(fn, (params, caches, token, pos), (psh, csh, tok_sh, pos_sh),
                (None, csh), {"kind": "decode"})


# ---------------------------------------------------------------------------
# Block-level cells (roofline accounting: cost = full + (R-1) x block)
# ---------------------------------------------------------------------------

def build_block_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     attn_impl: str = "xla") -> Cell:
    """One layer-block lowered standalone with identical shardings.

    XLA cost analysis counts while-loop bodies once; the per-(arch,shape)
    roofline is ``cost(full scanned graph) + (n_repeats-1) * cost(block)``
    (DESIGN.md §5, validated against a full unroll in EXPERIMENTS.md).
    """
    from repro.models import blocks as blocks_lib
    from repro.models.common import stack_specs

    b = shape.global_batch
    enc_len, s = _seq_split(cfg, shape)
    block_specs_tree = tuple(
        stack_specs(t, 1) for t in blocks_lib.block_specs(
            cfg, cross=cfg.encoder_decoder))
    serve_fsdp = (shape.kind != "train") and (cfg.fsdp or
                                              shd.serve_needs_fsdp(cfg, mesh))
    bsh = shd.param_shardings(block_specs_tree, cfg, mesh,
                              fsdp=cfg.fsdp if shape.kind == "train" else serve_fsdp)
    bparams = _with_sharding(abstract_params(block_specs_tree), bsh)
    hsp = shd.batch_pspec(mesh, b, extra_dims=2,
                          strategy=cfg.shard_strategy)
    if (cfg.shard_strategy in ("seq_dp", "ep_seq") and "model" in mesh.axis_names
            and shape.kind != "decode" and s % mesh.shape["model"] == 0):
        hsp = P(hsp[0], "model", None)  # sequence over model (seq_dp)
    h_sh = NamedSharding(mesh, hsp)

    if shape.kind == "decode":
        # single-layer caches (leading dim 1): slicing layer 0 out of the full
        # (R, ...) stack would charge the whole stack's bytes to the slice op
        # in pre-fusion cost analysis and swamp the per-layer numbers
        single = []
        for pos_i, lspec in enumerate(cfg.pattern):
            layer = blocks_lib.layer_cache_specs(
                cfg, lspec, b, s, enc_len if cfg.encoder_decoder else 0)
            single.append(jax.tree.map(
                lambda t: jax.ShapeDtypeStruct((1,) + t.shape, t.dtype),
                layer))
        cspecs = tuple(single)
        csh = shd.cache_shardings(cspecs, cfg, mesh, b)
        caches = _with_sharding(cspecs, csh)
        h = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.dtype(cfg.dtype),
                                 sharding=h_sh)
        pos_sh = NamedSharding(mesh, P())
        pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=pos_sh)

        def fn(bp, c, hh, pp):
            bp1 = jax.tree.map(lambda a: a[0], bp)
            c1 = jax.tree.map(lambda a: a[0], c)
            out, nc = blocks_lib.block_decode(bp1, hh, c1, pp, cfg, angles=None)
            # keep the stacked layout so out_shardings can pin the cache
            # placement (otherwise XLA picks one and the boundary reshard
            # pollutes the per-layer wire accounting)
            nc = jax.tree.map(lambda a: a[None], nc)
            return out, nc

        return Cell(fn, (bparams, caches, h, pos), (bsh, csh, h_sh, pos_sh),
                    (None, csh), {"kind": "decode_block"})

    h = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype),
                             sharding=h_sh)

    if shape.kind == "train":
        # Grads are taken wrt ACTIVATIONS only: the per-layer parameter-grad
        # reduction is amortized into one stacked all-reduce in the real
        # scanned graph (already counted in the full-graph artifact), so a
        # per-block param AR/RS would double-count wire bytes.  Weight
        # all-gathers (fsdp) still appear — W is used in fwd, remat and dgrad.
        def fn(bp, hh):
            bp1 = jax.tree.map(lambda a: a[0], bp)

            def loss(h_):
                out, aux = blocks_lib.block_fwd(bp1, h_, cfg, None, True,
                                                attn_impl=attn_impl)
                return jnp.mean(out.astype(jnp.float32) ** 2) + aux

            if cfg.remat == "full":
                lossf = jax.checkpoint(loss, prevent_cse=False)
            else:
                lossf = loss
            return jax.grad(lossf)(hh)

        return Cell(fn, (bparams, h), (bsh, h_sh), None,
                    {"kind": "train_block"})

    def fn(bp, hh):
        bp1 = jax.tree.map(lambda a: a[0], bp)
        out, _ = blocks_lib.block_fwd(bp1, hh, cfg, None, True,
                                      attn_impl=attn_impl)
        return out

    return Cell(fn, (bparams, h), (bsh, h_sh), None, {"kind": "prefill_block"})
