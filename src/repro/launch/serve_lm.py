"""Batched LM decode demo: prefill + decode with the sequence-sharded cache.

(Renamed from ``repro.launch.serve`` — the bare ``serve`` name now means the
query server, ``repro.launch.serve_queries``.)

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-1.7b \
        --preset ci --batch 4 --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", default="ci", choices=["ci", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "ci":
        cfg = cfg.smoke()
    params = lm.init_model(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    cache_len = s + args.decode_steps
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(b, s)),
                          jnp.int32)

    decode = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))

    # prefill by replay (exact; see lm.prefill docstring)
    t0 = time.time()
    caches = lm.init_cache(cfg, b, cache_len)
    tok = prompts[:, :1]
    logits = None
    for t in range(s):
        logits, caches = decode(params, caches, prompts[:, t:t + 1],
                                jnp.int32(t))
    t1 = time.time()

    out_tokens = []
    tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    for t in range(args.decode_steps):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, caches = decode(params, caches, tok, jnp.int32(s + t))
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    t2 = time.time()
    gen = np.stack(out_tokens, 1)
    print(f"[serve] arch={cfg.name} batch={b} prefill={s} tok "
          f"({(t1-t0):.2f}s) decode={args.decode_steps} tok "
          f"({(t2-t1):.2f}s, {b*args.decode_steps/(t2-t1):.1f} tok/s)")
    print(f"[serve] sample generation ids: {gen[0][:12].tolist()}")
    assert gen.shape == (b, args.decode_steps)


if __name__ == "__main__":
    main()
