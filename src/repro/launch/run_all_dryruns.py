"""Sequential subprocess driver for the full dry-run sweep.

One subprocess per cell bounds compiler memory and makes the sweep resumable
(cells with an existing JSON are skipped).  Full cells run on both meshes;
block cells (roofline scan-body scaling) run single-pod only (§Roofline).

    PYTHONPATH=src python -m repro.launch.run_all_dryruns [--force] [--only substr]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parents[3]
OUT_DIR = REPO / "experiments" / "dryrun"


def cells():
    from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_is_runnable
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                yield (arch, shape.name, mesh, False)
            if cell_is_runnable(arch, shape):
                yield (arch, shape.name, "single", True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    todo = list(cells())
    t_start = time.time()
    for i, (arch, shape, mesh, block) in enumerate(todo):
        tag = f"{arch}__{shape}__{mesh}" + ("__block" if block else "")
        if args.only and args.only not in tag:
            continue
        out = OUT_DIR / f"{tag}.json"
        if out.exists() and not args.force:
            try:
                if json.loads(out.read_text()).get("status") in ("ok", "skipped"):
                    continue
            except Exception:
                pass
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh]
        if block:
            cmd.append("--block")
        t0 = time.time()
        try:
            subprocess.run(cmd, cwd=REPO, timeout=args.timeout,
                           env={**__import__("os").environ,
                                "PYTHONPATH": str(REPO / "src")})
        except subprocess.TimeoutExpired:
            out.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "block": block,
                "status": "error", "error": f"timeout>{args.timeout}s"}))
        print(f"  [{i+1}/{len(todo)}] {tag} ({time.time()-t0:.0f}s, "
              f"total {(time.time()-t_start)/60:.1f}m)", flush=True)


if __name__ == "__main__":
    main()
