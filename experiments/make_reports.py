"""Generate the data-driven sections of EXPERIMENTS.md from the dry-run JSONs
and the roofline analysis.

    PYTHONPATH=src python experiments/make_reports.py
"""
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.configs import ASSIGNED_ARCHS, SHAPES, cell_is_runnable, get_config  # noqa: E402
from repro.launch import roofline  # noqa: E402

DRY = REPO / "experiments" / "dryrun"


def load(tag):
    p = DRY / f"{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | status | compile (s) | peak mem/dev (GiB) | HLO flops/dev | wire bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                d = load(f"{arch}__{shape.name}__{mesh}")
                if d is None:
                    continue
                if d["status"] == "skipped":
                    lines.append(f"| {arch} | {shape.name} | {mesh} | "
                                 f"skipped (full attn @500k) | — | — | — | — |")
                    continue
                peak = (d.get("peak_memory_bytes") or 0) / 2 ** 30
                lines.append(
                    f"| {arch} | {shape.name} | {mesh} | {d['status']} | "
                    f"{d.get('compile_s', '—')} | {peak:.2f} | "
                    f"{d.get('flops_per_device', 0):.2e} | "
                    f"{d.get('wire_bytes_per_device', 0):.2e} |")
    return "\n".join(lines)


def roofline_md() -> str:
    rows = roofline.full_table("single")
    lines = ["| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO-useful | roofline % | peak GiB | HLO/analytic flops |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {100*r['roofline_fraction']:.2f} | "
            f"{r['peak_memory_gib']:.2f} | {r['hlo_vs_analytic_flops']:.3f} |")
    return "\n".join(lines)


def perf_compare(arch, shape_name, variant):
    from repro.configs import SHAPE_BY_NAME
    shape = SHAPE_BY_NAME[shape_name]
    base = roofline.analyze_cell(arch, shape, "single")
    var = roofline.analyze_cell(arch, shape, "single", variant=variant)
    return base, var


def main():
    out = REPO / "experiments" / "generated_tables.md"
    parts = ["## Generated: §Dry-run table\n", dryrun_table(),
             "\n\n## Generated: §Roofline table (single-pod, baseline megatron)\n",
             roofline_md(), "\n"]
    out.write_text("\n".join(parts))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
